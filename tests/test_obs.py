"""Observability layer tests (ISSUE 10 / DESIGN.md 1j).

Covers the four obs surfaces and their acceptance bars:

* histogram quantile estimates pinned against numpy order statistics
  (within one bucket factor — the documented estimator contract);
* snapshot/delta/reset coherence, including two services interleaving
  publishes into the shared registry;
* span nesting and Chrome-trace export schema (Perfetto-loadable), for a
  real ``PairwiseService.similarity`` request;
* the comm-ledger reconciler: measured/predicted exactly 1.0 on the
  unreplicated executors, exactly r on the coded executor (r=2 measured
  assembly bytes matching ``coded_assembly_model`` under a real 8-device
  mesh, in a subprocess), anomaly events on drift;
* the FUSED_STATS shared-dict hazard regression: the default registry
  fused executor owns instance-scoped stats, while ``engine.fused_stats``
  stays live as the aggregate view;
* cache eviction events from the jit/block/plan caches.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.core import plan_a2a
from repro.mapreduce import engine as mr_engine
from repro.mapreduce import get_executor, pairwise_similarity
from repro.obs import EVENTS, LEDGER, REGISTRY, TRACER
from repro.obs.metrics import Histogram, MetricsRegistry, \
    exponential_buckets


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Each test sees a clean slate and leaves one behind (the registry /
    ledger / tracer are process-global by design)."""
    obs.reset_all()
    obs.configure(enabled=True)
    yield
    obs.reset_all()
    obs.configure(enabled=True)


def _zipf_table(m=64, d=8, q=1.0, seed=0):
    rng = np.random.default_rng(seed)
    w = np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45 * q)
    x = rng.normal(size=(m, d)).astype(np.float32)
    return x, w


# ---------------------------------------------------------------- histograms
def test_histogram_quantiles_vs_numpy():
    """p50/p90/p99 within one bucket factor of numpy's exact order
    statistics on a lognormal sample (fixed seed)."""
    rng = np.random.default_rng(42)
    sample = rng.lognormal(mean=-3.0, sigma=1.0, size=5000)
    h = Histogram()
    for v in sample:
        h.observe(float(v))
    factor = 1.25                      # DEFAULT_BUCKETS growth factor
    for q in (0.50, 0.90, 0.99):
        exact = float(np.quantile(sample, q))
        est = h.quantile(q)
        assert exact / factor <= est <= exact * factor, (q, est, exact)
    assert h.count == 5000
    assert h.mean == pytest.approx(sample.mean(), rel=1e-9)
    assert h.max == pytest.approx(sample.max())
    assert h.min == pytest.approx(sample.min())


def test_histogram_overflow_and_empty():
    h = Histogram(bounds=exponential_buckets(1.0, 2.0, 4))  # ..., 8.0
    assert h.quantile(0.5) == 0.0      # empty
    h.observe(100.0)                   # overflow bucket
    assert h.quantile(0.5) == 100.0    # overflow reports tracked max
    assert h.summary()["p99"] == 100.0


def test_registry_snapshot_delta_reset():
    r = MetricsRegistry()
    r.counter("req", executor="fused").inc()
    r.counter("req", executor="dense").inc(3)
    r.gauge("load", executor="fused").set(0.5)
    r.histogram("lat", executor="fused").observe(0.01)
    before = r.snapshot()
    r.counter("req", executor="fused").inc(2)
    r.histogram("lat", executor="fused").observe(0.02)
    after = r.snapshot()

    d = MetricsRegistry.delta(before, after)
    assert d["counters"] == {"req{executor=fused}": 2}
    assert d["histograms"]["lat{executor=fused}"]["count"] == 1
    assert r.counter_total("req") == 6
    assert r.counter_total("req", executor="dense") == 3

    r.reset()
    snap = r.snapshot()
    assert snap["counters"]["req{executor=fused}"] == 0
    assert snap["histograms"]["lat{executor=fused}"]["count"] == 0


def test_kill_switch_disables_all_surfaces():
    prior = obs.enabled()
    try:
        obs.configure(enabled=False)
        REGISTRY.counter("dead").inc()
        REGISTRY.histogram("dead_h").observe(1.0)
        with obs.span("dead_span") as s:
            assert s is None
        assert EVENTS.emit("dead_event") is None
        assert LEDGER.record(
            executor="x", workload="y", predicted_rows=1.0, lb_rows=1.0,
            plan_slots=1, measured_slots=1, d=1) is None
        assert REGISTRY.counter("dead").value == 0
        assert len(TRACER.spans()) == 0
    finally:
        obs.configure(enabled=prior)


# -------------------------------------------------------------------- spans
def test_span_nesting_and_chrome_trace_schema():
    with obs.span("outer", workload="pairs") as outer:
        with obs.span("inner") as inner:
            pass
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert outer.duration >= inner.duration >= 0.0

    doc = TRACER.chrome_trace()
    text = json.dumps(doc)             # must be JSON-serializable
    doc = json.loads(text)
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] == "X"
        for key in ("name", "ts", "dur", "pid", "tid", "args"):
            assert key in ev, ev
    by_name = {ev["name"]: ev for ev in evs}
    assert by_name["inner"]["args"]["parent"] == \
        by_name["outer"]["args"]["span_id"]
    assert by_name["outer"]["args"]["workload"] == "pairs"


def test_service_request_trace_exports(tmp_path):
    """A real PairwiseService.similarity request produces a schema-valid
    Chrome trace with the documented span hierarchy."""
    from repro.serve import PairwiseService

    x, w = _zipf_table()
    svc = PairwiseService(q=1.0, executor="fused")
    svc.similarity(x, weights=w)

    path = tmp_path / "trace.json"
    TRACER.export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    names = [ev["name"] for ev in doc["traceEvents"]]
    assert "request" in names
    assert "plan" in names and "execute" in names
    by_name = {ev["name"]: ev for ev in doc["traceEvents"]}
    # plan and execute nest under the request span
    req_id = by_name["request"]["args"]["span_id"]
    assert by_name["plan"]["args"]["parent"] == req_id
    assert by_name["execute"]["args"]["parent"] == req_id
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X" and ev["dur"] >= 0


# ------------------------------------------------------------- comm ledger
def test_reconciler_dense_exact():
    """Dense executor: measured == planned shuffle exactly (ratio 1.0,
    zero tolerance), and measured_over_lb = comm_cost / lower_bound."""
    x, w = _zipf_table()
    sims, plan, _ = pairwise_similarity(x, q=1.0, weights=w,
                                        executor="dense")
    rec = LEDGER.last()
    assert rec is not None and rec.executor == "dense"
    assert rec.measured_over_predicted == 1.0
    assert not rec.anomaly
    assert rec.measured_over_lb == pytest.approx(
        float(plan.comm_cost) / float(plan.lower_bound))
    # gathered bytes = executed slot count x row bytes (slots are the
    # copy-count ledger; predicted_bytes is the weighted-row view)
    assert rec.gathered_bytes == rec.measured_slots * rec.d * rec.itemsize
    assert rec.measured_slots == int(np.asarray(plan.mask).sum())


@pytest.mark.parametrize("name", ["dense", "bucketed", "fused", "sharded",
                                  "coded", "streaming"])
def test_reconciler_reports_on_every_executor(name):
    """All six registry executors file a reconciliation record per
    request, with both ratios present and the ratio matching the
    executor's replication (1.0 everywhere at replication 1)."""
    x, w = _zipf_table()
    seq0 = LEDGER.seq
    pairwise_similarity(x, q=1.0, weights=w, executor=name)
    recs = [r for r in LEDGER.records(since_seq=seq0)
            if r.executor == name]
    assert recs, f"{name} filed no ledger record"
    rec = recs[-1]
    assert rec.measured_over_predicted == pytest.approx(rec.replication)
    assert rec.measured_over_lb is not None and rec.measured_over_lb >= 1.0
    assert not rec.anomaly


def test_reconciler_x2y_rectangular():
    from repro.mapreduce import x2y_similarity

    rng = np.random.default_rng(3)
    x = rng.normal(size=(32, 6)).astype(np.float32)
    y = rng.normal(size=(20, 6)).astype(np.float32)
    seq0 = LEDGER.seq
    x2y_similarity(jnp.asarray(x), jnp.asarray(y), q=2.0)
    recs = LEDGER.records(since_seq=seq0)
    assert recs and recs[-1].workload == "x2y"
    assert recs[-1].measured_over_predicted == 1.0


def test_reconciler_anomaly_event():
    """A measured/predicted drift beyond tolerance raises an anomaly:
    flagged record, ledger.anomalies counter, comm_anomaly event."""
    rec = LEDGER.record(
        executor="dense", workload="pairs", predicted_rows=100.0,
        lb_rows=80.0, plan_slots=100, measured_slots=150, d=8)
    assert rec.anomaly
    assert rec.measured_over_predicted == 1.5
    assert REGISTRY.counter_total("ledger.anomalies", executor="dense") == 1
    evs = EVENTS.events(kind="comm_anomaly")
    assert evs and evs[-1]["measured_over_predicted"] == 1.5

    ok = LEDGER.record(
        executor="dense", workload="pairs", predicted_rows=100.0,
        lb_rows=80.0, plan_slots=100, measured_slots=100, d=8)
    assert not ok.anomaly


def test_reconciler_streaming_delta_below_lb():
    """Streaming edits ship only dirty reducers: the delta's
    measured_over_lb sits *below* 1 against the full instance's bound —
    the quantified streaming savings."""
    from repro.serve import PairwiseService

    x, w = _zipf_table(m=96)
    svc = PairwiseService(q=1.0, executor="streaming")
    svc.load_table(x, w)
    rng = np.random.default_rng(7)
    _, info = svc.add_input(rng.normal(size=(1, 8)).astype(np.float32),
                            0.1)
    comm = info.get("comm")
    assert comm is not None
    assert comm["measured_over_predicted"] == 1.0
    assert comm["measured_over_lb"] is not None
    assert comm["measured_over_lb"] < 1.0


# ------------------------------------------- coded r=2 vs analytic model
CODED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import plan_a2a
    from repro.mapreduce import pairwise_similarity
    from repro.mapreduce.executors import coded_assembly_model, \\
        make_executor
    from repro.obs import LEDGER

    rng = np.random.default_rng(0)
    m = 48
    w = np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45)
    x = jnp.asarray(rng.normal(size=(m, 6)).astype(np.float32))
    ex = make_executor("coded", replication=2)
    sims, plan, _ = pairwise_similarity(x, q=1.0, weights=w, executor=ex)

    recs = [r for r in LEDGER.records() if r.executor == "coded"]
    assert recs, "coded executor filed no ledger record"
    rec = recs[-1]
    # measured slots = r x planned slots, exactly
    assert rec.measured_over_predicted == 2.0, rec.summary()
    assert rec.replication == 2.0
    assert not rec.anomaly, rec.summary()
    assert rec.measured_over_lb is not None

    # measured assembly bytes match the analytic coded model exactly
    model = coded_assembly_model(plan, 8, 2, m, itemsize=4)
    got = rec.meta["assembly_bytes_per_shard"]
    want = model["assembly_bytes_per_shard"]
    assert got == want, (got, want)
    assert rec.assembled_bytes == 8 * want, rec.assembled_bytes
    print("CODED_LEDGER_OK", rec.measured_over_predicted)
""")


def test_coded_r2_reconciles_against_model_on_8_device_mesh():
    """Coded r=2 on a real 8-shard mesh: the reconciler's ratio is
    exactly 2.0 and its measured assembly bytes equal
    ``coded_assembly_model`` (subprocess: the main test process keeps its
    default device count)."""
    res = subprocess.run(
        [sys.executable, "-c", CODED_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "HOME": os.environ.get("HOME", "/tmp")},
    )
    assert "CODED_LEDGER_OK" in res.stdout, res.stdout + res.stderr


# ------------------------------------------------------ interleaved services
def test_interleaved_services_snapshot_coherent():
    """Two services with different executors/tenants interleave requests:
    per-label series stay separate, snapshot delta accounts for exactly
    the window's requests, reset() zeroes both without breaking live
    handles."""
    from repro.serve import PairwiseService

    x, w = _zipf_table()
    a = PairwiseService(q=1.0, executor="bucketed", tenant="a")
    b = PairwiseService(q=1.0, executor="fused", tenant="b")
    a.similarity(x, weights=w)
    before = REGISTRY.snapshot()
    b.similarity(x, weights=w)
    a.similarity(x, weights=w)
    b.similarity(x, weights=w)
    after = REGISTRY.snapshot()

    d = MetricsRegistry.delta(before, after)
    key_a = "serve.requests{executor=bucketed,tenant=a,workload=pairs}"
    key_b = "serve.requests{executor=fused,tenant=b,workload=pairs}"
    assert d["counters"][key_a] == 1
    assert d["counters"][key_b] == 2
    assert after["counters"][key_a] == 2
    assert after["counters"][key_b] == 2

    REGISTRY.reset()
    b.similarity(x, weights=w)        # live handles keep publishing
    assert REGISTRY.snapshot()["counters"][key_b] == 1
    assert REGISTRY.snapshot()["counters"][key_a] == 0


# ----------------------------------------------------- FUSED_STATS regression
def test_default_fused_executor_owns_its_stats():
    """Regression (shared-dict hazard): the registry's default fused
    executor must NOT alias engine.FUSED_STATS — an Executor.reset() on
    it would have zeroed every other caller's counters."""
    ex = get_executor("fused")
    assert ex._stats is not mr_engine.FUSED_STATS


def test_fused_stats_is_aggregate_view():
    """engine.fused_stats() keeps its documented contract: a live
    aggregate over fused dispatches, including the default registry
    instance (the before/after delta used by the kernel tests)."""
    x, w = _zipf_table()
    mr_engine.reset_fused_stats()
    before = mr_engine.fused_stats()
    assert before == {"calls": 0, "kernel": 0, "streamed": 0,
                      "fallbacks": 0}
    pairwise_similarity(x, q=1.0, weights=w, executor="fused")
    after = mr_engine.fused_stats()
    assert after["calls"] == 1
    assert after["streamed"] + after["kernel"] == 1
    # instance-scoped stats saw the same dispatch
    assert get_executor("fused").stats()["calls"] >= 1


# ------------------------------------------------------------------- events
def test_jit_cache_eviction_emits_event():
    """Each jit-cache eviction bumps cache.evictions{cache=jit} and files
    a structured cache_eviction event naming the evicted key."""
    for i in range(3):
        mr_engine._JIT_CACHE[("obs_test", i)] = i
    mr_engine._evict_oldest()
    mr_engine._evict_oldest()
    assert REGISTRY.counter_total("cache.evictions", cache="jit") == 2
    evs = EVENTS.events(kind="cache_eviction")
    assert len(evs) == 2
    assert all(e["cache"] == "jit" for e in evs)
    for key in [k for k in mr_engine._JIT_CACHE
                if isinstance(k, tuple) and k and k[0] == "obs_test"]:
        del mr_engine._JIT_CACHE[key]


def test_event_log_ring_and_counts():
    for i in range(5):
        EVENTS.emit("unit_test_event", i=i)
    assert EVENTS.counts()["unit_test_event"] == 5
    tail = EVENTS.events(kind="unit_test_event", last=2)
    assert [e["i"] for e in tail] == [3, 4]
    seqs = [e["seq"] for e in EVENTS.events(kind="unit_test_event")]
    assert seqs == sorted(seqs)
