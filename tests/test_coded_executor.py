"""Coded executor + replicated partitioning: differential and ledger tests.

The coded executor trades replication for cross-shard assembly traffic
(Afrati et al., arXiv:1206.4377).  It must stay a pure execution-plan
change — identical outputs to the dense/bucketed executors on random,
Zipf-skewed, and degenerate schemas — while ``partition_plan(...,
replication=r)`` keeps the coverage/capacity/comm ledgers exact: the
primary LPT assignment is untouched, every reducer is held by exactly r
shards, and the replica slot ledger sums to exactly r x the unreplicated
shipped weight.  The in-process tests run at the main process's device
count (1 on plain CPU); the subprocess test forces an 8-device CPU mesh
to exercise the real residual all-to-all and compare its measured HLO
bytes against the sharded executor's assembly all-gather.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import partition_plan, plan_a2a
from repro.mapreduce import (
    build_plan,
    get_executor,
    list_executors,
    make_executor,
    pairwise_similarity,
    x2y_similarity,
)
from repro.mapreduce.executors import (
    choose_replication,
    coded_assembly_model,
)


def _weights(kind: str, m: int, seed: int, q: float = 1.0):
    rng = np.random.default_rng(seed)
    return {
        "uniform": lambda: rng.uniform(0.05, 0.33, m),
        "zipf": lambda: np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45 * q),
        "one-giant": lambda: np.concatenate(
            [[0.8 * q], rng.uniform(0.02, 0.1, m - 1)]),
    }[kind]()


def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _zipf_plan(m: int, seed: int = 0):
    w = _weights("zipf", m, seed=seed)
    return build_plan(plan_a2a(w, 1.0)), w


# ------------------------------------------------- replicated partitioning
def _check_replication_ledger(plan, num_shards, r):
    part = partition_plan(plan, num_shards, replication=r)
    base = partition_plan(plan, num_shards)
    R0 = plan.num_reducers

    # primary assignment identical to the unreplicated partition
    for rows, brows in zip(part.shard_rows, base.shard_rows):
        np.testing.assert_array_equal(rows, brows)
    # coverage/capacity untouched: sub-plans carry idx/mask verbatim
    for rows, sub in zip(part.shard_rows, part.shards):
        assert sub.num_reducers == len(rows)
        np.testing.assert_array_equal(sub.idx, plan.idx[rows])
        np.testing.assert_array_equal(sub.mask, plan.mask[rows])
    assert float(part.comm_cost.sum()) == pytest.approx(plan.comm_cost)

    # every reducer held by exactly r shards, holder sets nest the
    # primary assignment (replication only ever ADDS holders)
    held = np.zeros((num_shards, R0), dtype=np.int64)
    for s, rows in enumerate(part.replica_rows):
        held[s, np.asarray(rows, dtype=np.int64)] += 1
        assert set(np.asarray(part.shard_rows[s]).tolist()) <= set(
            np.asarray(rows).tolist())
    if R0:
        np.testing.assert_array_equal(held.max(axis=0), np.ones(R0))
        np.testing.assert_array_equal(held.sum(axis=0), np.full(R0, r))

    # replica ledger: exactly r x the unreplicated shipped weight
    assert int(part.replica_slots.sum()) == r * int(part.shipped_rows.sum())
    assert int(part.shipped_rows.sum()) == int(plan.mask.sum())
    rep = part.report()
    assert rep["replication"] == r
    assert rep["replica_balance_factor"] >= 1.0 or R0 == 0
    return part


class TestReplicatedPartition:
    @pytest.mark.parametrize("kind", ["uniform", "zipf", "one-giant"])
    @pytest.mark.parametrize("num_shards,r", [(4, 2), (8, 2), (8, 4),
                                              (8, 8), (3, 3)])
    def test_ledger_exact(self, kind, num_shards, r):
        m = 37
        plan = build_plan(plan_a2a(_weights(kind, m, seed=m), 1.0))
        _check_replication_ledger(plan, num_shards, r)

    def test_r1_matches_unreplicated(self):
        plan, _ = _zipf_plan(40)
        part = partition_plan(plan, 4, replication=1)
        assert part.replication == 1
        for rows, rrows in zip(part.shard_rows, part.replica_rows):
            np.testing.assert_array_equal(np.sort(rows), np.sort(rrows))

    def test_replication_out_of_range_rejected(self):
        plan, _ = _zipf_plan(20)
        with pytest.raises(AssertionError):
            partition_plan(plan, 4, replication=5)
        with pytest.raises(AssertionError):
            partition_plan(plan, 4, replication=0)

    def test_holder_sets_nested_across_rates(self):
        """Raising r only adds holders — the monotone-frontier invariant
        (a block served locally at rate r stays local at r+1)."""
        plan, _ = _zipf_plan(64)
        prev = None
        for r in (1, 2, 4, 8):
            part = partition_plan(plan, 8, replication=r)
            cur = [set(np.asarray(rows).tolist())
                   for rows in part.replica_rows]
            if prev is not None:
                for a, b in zip(prev, cur):
                    assert a <= b
            prev = cur

    def test_empty_plan(self):
        plan = build_plan(plan_a2a([], 1.0))
        part = partition_plan(plan, 4, replication=2)
        assert part.replication == 2
        assert all(len(rows) == 0 for rows in part.replica_rows)

    @given(st.integers(min_value=5, max_value=60),
           st.integers(min_value=0, max_value=2 ** 31 - 1),
           st.integers(min_value=2, max_value=8),
           st.integers(min_value=2, max_value=8))
    @settings(max_examples=25, deadline=None)
    def test_property_ledger_exact(self, m, seed, num_shards, r):
        """Property: for any Zipf profile and any 2 <= r <= S, replication
        preserves coverage/capacity and the replica ledger sums to exactly
        r x the unreplicated shipped weight."""
        if r > num_shards:
            r = num_shards
        rng = np.random.default_rng(seed)
        w = np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45)
        plan = build_plan(plan_a2a(w, 1.0))
        _check_replication_ledger(plan, num_shards, r)


# ------------------------------------------------------------- differential
KINDS = ["uniform", "zipf", "one-giant"]


class TestCodedExecutorDifferential:
    def test_registered(self):
        assert "coded" in list_executors()
        assert get_executor("coded").name == "coded"

    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("m", [5, 29])
    def test_pairwise_coded_matches_dense(self, kind, m):
        w = _weights(kind, m, seed=m)
        rng = np.random.default_rng(m)
        x = _rand(rng, (m, 6))
        schema = plan_a2a(w, 1.0)
        s_d, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        executor="dense")
        s_c, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        executor="coded")
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_d),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("metric", ["dot", "l2", "cosine"])
    def test_metrics_agree(self, metric):
        m = 26
        w = _weights("zipf", m, seed=7)
        rng = np.random.default_rng(7)
        x = _rand(rng, (m, 8))
        schema = plan_a2a(w, 1.0)
        s_b, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        metric=metric, executor="bucketed")
        s_c, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        metric=metric, executor="coded")
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)

    def test_x2y_coded_matches_bucketed(self):
        rng = np.random.default_rng(11)
        nx, ny, d = 21, 17, 5
        xw = rng.uniform(0.05, 0.3, nx)
        yw = rng.uniform(0.05, 0.3, ny)
        xt = _rand(rng, (nx, d))
        yt = _rand(rng, (ny, d))
        s_b, _, sch = x2y_similarity(xt, yt, q=1.0, wx=xw, wy=yw,
                                     executor="bucketed")
        s_c, _, _ = x2y_similarity(xt, yt, q=1.0, wx=xw, wy=yw, schema=sch,
                                   executor="coded")
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)

    def test_single_input_degenerate(self):
        x = jnp.ones((1, 4), jnp.float32)
        s_c, _, _ = pairwise_similarity(x, q=1.0, weights=[0.3],
                                        executor="coded")
        s_b, _, _ = pairwise_similarity(x, q=1.0, weights=[0.3],
                                        executor="bucketed")
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_b))

    def test_non_gram_reducer_falls_back(self):
        m = 17
        w = _weights("zipf", m, seed=3)
        plan = build_plan(plan_a2a(w, 1.0))
        rng = np.random.default_rng(5)
        x = _rand(rng, (m, 4))

        def colsum(blk, msk):
            return jnp.sum(blk * msk[:, None], axis=0)

        ex = make_executor("coded")
        from repro.mapreduce import run_reducers_bucketed
        out = ex.run(x, plan, colsum)
        buck = run_reducers_bucketed(x, plan, colsum)
        np.testing.assert_allclose(np.asarray(out), np.asarray(buck),
                                   rtol=1e-5, atol=1e-5)
        assert ex.stats()["fallbacks"] == 1

    def test_coded_telemetry_recorded(self):
        m = 19
        w = _weights("uniform", m, seed=2)
        rng = np.random.default_rng(2)
        x = _rand(rng, (m, 4))
        ex = make_executor("coded")
        schema = plan_a2a(w, 1.0)
        pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                            executor=ex)
        stats = ex.stats()
        assert stats["coded"] == 1
        assert stats["replication"] >= 1
        assert 0.0 <= stats["local_fraction"] <= 1.0
        assert stats["local_entries"] + stats["residual_entries"] > 0


# ---------------------------------------------------------- traffic model
class TestCodedModelAndChooser:
    def test_entries_conserved_across_rates(self):
        """Every needed Gram entry is served exactly once at every r —
        replication moves entries between the local and residual ledgers,
        it never drops or duplicates them."""
        plan, _ = _zipf_plan(64)
        totals = set()
        for r in (1, 2, 4, 8):
            rec = coded_assembly_model(plan, 8, r, 64)
            totals.add(rec["local_entries"] + rec["residual_entries"])
        assert len(totals) == 1

    def test_local_fraction_tracks_replication(self):
        """With contiguous row-slices, each replica holder serves ~1/S of
        a block's rows locally: local fraction grows with r and hits 1.0
        at full replication."""
        plan, _ = _zipf_plan(64)
        fracs = [coded_assembly_model(plan, 8, r, 64)["local_fraction"]
                 for r in (1, 2, 4, 8)]
        assert all(b >= a for a, b in zip(fracs, fracs[1:]))
        assert fracs[-1] == 1.0

    def test_assembly_bytes_monotone_in_r(self):
        plan, _ = _zipf_plan(96)
        b = [coded_assembly_model(plan, 8, r, 96)[
            "assembly_bytes_per_shard"] for r in (1, 2, 4, 8)]
        assert all(y <= x for x, y in zip(b, b[1:])), b

    def test_chooser_returns_frontier_point(self):
        plan, _ = _zipf_plan(64)
        best_r, frontier = choose_replication(plan, 8, 64, 16)
        assert best_r in [rec["replication"] for rec in frontier]
        best = [rec for rec in frontier
                if rec["replication"] == best_r][0]
        assert all(best["total_comm_bytes"] <= rec["total_comm_bytes"]
                   for rec in frontier)
        # shipping term is exact: r x the schema's comm volume
        for rec in frontier:
            assert rec["shipped_bytes"] == pytest.approx(
                rec["replication"] * plan.comm_cost * 16 * 4)


# ------------------------------------------------- forced 8-device CPU mesh
SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import plan_a2a
    from repro.launch.roofline import collective_bytes
    from repro.mapreduce import get_executor, pairwise_similarity

    rng = np.random.default_rng(0)
    for kind in ("uniform", "zipf", "one-giant"):
        m = 48
        if kind == "uniform":
            w = rng.uniform(0.05, 0.33, m)
        elif kind == "zipf":
            w = np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45)
        else:
            w = np.concatenate([[0.8], rng.uniform(0.02, 0.1, m - 1)])
        x = jnp.asarray(rng.normal(size=(m, 6)).astype(np.float32))
        schema = plan_a2a(w, 1.0)
        s_d, plan, _ = pairwise_similarity(x, q=1.0, weights=w,
                                           schema=schema, executor="dense")
        s_c, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        executor="coded")
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_d),
                                   rtol=1e-4, atol=1e-4)
    st = get_executor("coded").stats()
    assert st["num_shards"] == 8, st
    assert st["replication"] == 2, st
    assert st["residual_entries"] > 0, st

    # the coded residual all-to-all must move fewer bytes than the
    # sharded executor's assembly all-gather on the same plan
    hlo_s = get_executor("sharded").lower(
        (m, 6), plan, metric="dot", m=m).compile().as_text()
    hlo_c = get_executor("coded").lower(
        (m, 6), plan, metric="dot", m=m, replication=2).compile().as_text()
    b_s = collective_bytes(hlo_s)["total"]
    b_c = collective_bytes(hlo_c)["total"]
    assert collective_bytes(hlo_c)["all-to-all"] > 0, hlo_c[:2000]
    assert b_c < b_s, (b_c, b_s)
    print("CODED_OK", b_c / b_s)
""")


def test_coded_differential_on_8_device_mesh():
    """coded == dense under a real 8-shard mesh, and the residual
    all-to-all moves fewer HLO bytes than the sharded assembly gather
    (subprocess: the main test process keeps its default device count)."""
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "HOME": os.environ.get("HOME", "/tmp")},
    )
    assert "CODED_OK" in res.stdout, res.stdout + res.stderr
