"""HLO analyzer: while-loop trip multipliers, dot flops, collective model —
validated on (a) synthetic HLO text and (b) a real compiled jax program."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo_text

SYNTH = """
%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %it = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%it, %c), direction=LT
}

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %it = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %y = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add.1
  %one = s32[] constant(1)
  %nit = s32[] add(%it, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%nit, %ar)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


class TestSyntheticHLO:
    def test_trip_count_multiplies_flops(self):
        stats = analyze_hlo_text(SYNTH, num_partitions=4)
        # dot: 2*8*8*8 = 1024 flops, x10 trips (+ the s32 add x10 = 10)
        assert stats.while_trip_counts == [10]
        assert abs(stats.flops - (1024 * 10 + 10)) < 1e-6

    def test_all_reduce_ring_model(self):
        stats = analyze_hlo_text(SYNTH, num_partitions=4)
        # AR of 8*8*4B=256B over groups of 4: 2*(3/4)*256 = 384 B x10 trips
        assert abs(stats.collective_bytes - 384 * 10) < 1e-6
        assert stats.collective_by_kind["all-reduce"] == stats.collective_bytes

    def test_traffic_counts_loop_body(self):
        stats = analyze_hlo_text(SYNTH, num_partitions=4, bf16_native=False)
        # dot (in+in+out = 3*256) + AR (256+256) appear x10
        assert stats.hbm_bytes >= 10 * (3 * 256)


class TestRealProgram:
    def test_scan_flops_counted(self):
        """A jitted lax.scan of matmuls must report ~trips x body flops."""
        n, trips = 64, 12

        def step(x, _):
            return jnp.tanh(x @ x), None

        def fn(x):
            y, _ = jax.lax.scan(step, x, None, length=trips)
            return y

        compiled = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((n, n), jnp.float32)).compile()
        stats = analyze_hlo_text(compiled.as_text(), num_partitions=1)
        want = 2 * n * n * n * trips
        assert want <= stats.flops <= want * 1.5, \
            (stats.flops, want, stats.while_trip_counts)

    def test_no_loop_program(self):
        compiled = jax.jit(lambda a, b: a @ b).lower(
            jax.ShapeDtypeStruct((32, 16), jnp.float32),
            jax.ShapeDtypeStruct((16, 8), jnp.float32)).compile()
        stats = analyze_hlo_text(compiled.as_text())
        want = 2 * 32 * 16 * 8
        assert want <= stats.flops <= want * 1.2
