"""Planner vs exhaustive optimum on tiny instances: bounded gap."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import plan_a2a
from repro.core.exact import optimal_a2a_bruteforce


class TestExactOptimal:
    def test_paper_example4_optimum_is_3_reducers(self):
        w = np.array([0.20, 0.20, 0.20, 0.19, 0.19, 0.18, 0.18])
        opt = optimal_a2a_bruteforce(w, 1.0)
        opt.validate("a2a")
        # the paper: best is 3 reducers at ~3q communication
        assert opt.num_reducers == 3
        assert opt.communication_cost() <= 3.01

    @given(st.lists(st.floats(0.05, 0.45), min_size=3, max_size=6),
           st.floats(1.0, 1.5))
    @settings(max_examples=25, deadline=None)
    def test_planner_within_3x_of_optimum(self, weights, q):
        w = np.asarray(weights)
        opt = optimal_a2a_bruteforce(w, q)
        if opt is None:
            pytest.skip("infeasible instance")
        opt.validate("a2a")
        plan = plan_a2a(w, q)
        plan.validate("a2a")
        ratio = plan.communication_cost() / max(opt.communication_cost(),
                                                1e-9)
        # tiny instances are the worst case for the asymptotic algorithms;
        # the portfolio still stays within a small constant
        assert ratio <= 3.0 + 1e-9, (ratio, w.tolist(), q)

    def test_optimum_never_beats_lower_bound_logic(self):
        rng = np.random.default_rng(0)
        for _ in range(5):
            w = rng.uniform(0.1, 0.4, 5)
            opt = optimal_a2a_bruteforce(w, 1.0)
            plan = plan_a2a(w, 1.0)
            assert opt.communication_cost() <= plan.communication_cost() + 1e-9
