"""MoE dispatch correctness: grouped argsort dispatch == dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.models.moe import moe_apply, moe_init
from repro.parallel.sharding import ShardingRules

# excluded from `make test-fast` (full arch/kernel e2e sweeps)
pytestmark = pytest.mark.slow


def _rules():
    mesh = make_mesh((1,), ("data",))
    return ShardingRules.create(mesh)


def dense_reference(params, x, top_k):
    """Every token through its top-k experts, no capacity limit."""
    B, S, d = x.shape
    E = params["router"].shape[1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(probs, top_k)
    gv = gv / jnp.sum(gv, axis=-1, keepdims=True)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(E):
        h = jnp.einsum("bsd,df->bsf", x, params["wi_gate"][e])
        u = jnp.einsum("bsd,df->bsf", x, params["wi_up"][e])
        ye = jnp.einsum("bsf,fd->bsd", jax.nn.silu(h) * u, params["wo"][e])
        w = jnp.sum(jnp.where(gi == e, gv, 0.0), axis=-1)     # (B, S)
        out = out + ye.astype(jnp.float32) * w[..., None]
    return out


@pytest.mark.parametrize("top_k,E", [(1, 4), (2, 4), (2, 8)])
def test_matches_dense_reference_no_drops(top_k, E):
    rng = np.random.default_rng(0)
    B, S, d, ff = 2, 32, 16, 32
    params, _ = moe_init(jax.random.key(0), d, ff, E, jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32))
    # capacity_factor = E/top_k => C = S, so nothing can drop
    y, aux = moe_apply(params, x, top_k=top_k,
                       capacity_factor=E / top_k, rules=_rules())
    ref = dense_reference(params, x, top_k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux["load_balance"]))


def test_capacity_drops_bounded():
    """With tight capacity, output is a (gated) subset — never NaN, and
    dropped tokens produce zeros."""
    rng = np.random.default_rng(1)
    B, S, d, ff, E = 2, 64, 8, 16, 4
    params, _ = moe_init(jax.random.key(1), d, ff, E, jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32))
    y, _ = moe_apply(params, x, top_k=2, capacity_factor=0.5,
                     rules=_rules())
    assert np.isfinite(np.asarray(y)).all()


def test_gate_weights_sum_preserved():
    """With capacity ample, per-token output equals gate-weighted expert
    mix; scaling x scales y (linearity through silu is not exact, so just
    check no token is double-counted via the scatter-add)."""
    rng = np.random.default_rng(2)
    B, S, d, ff, E = 1, 16, 8, 16, 4
    params, _ = moe_init(jax.random.key(2), d, ff, E, jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, d)).astype(np.float32))
    y1, _ = moe_apply(params, x, top_k=2, capacity_factor=2.0,
                      rules=_rules())
    y2, _ = moe_apply(params, x, top_k=2, capacity_factor=4.0,
                      rules=_rules())
    # more capacity cannot change already-routed tokens
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
