"""Optimizer + train-step substrate tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_lr,
    global_norm,
)


def quad_loss(params):
    return jnp.sum(jnp.square(params["w"] - 3.0)) \
        + jnp.sum(jnp.square(params["b"] + 1.0))


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=300,
                          weight_decay=0.0)
        params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        opt = adamw_init(params, cfg)
        for step in range(300):
            g = jax.grad(quad_loss)(params)
            params, opt, _ = adamw_update(g, opt, params,
                                          jnp.asarray(step), cfg)
        assert float(quad_loss(params)) < 1e-2

    def test_clipping_caps_update(self):
        cfg = AdamWConfig(clip_norm=1.0)
        params = {"w": jnp.zeros((8,))}
        opt = adamw_init(params, cfg)
        g = {"w": jnp.full((8,), 1e6)}
        _, _, metrics = adamw_update(g, opt, params, jnp.asarray(0), cfg)
        assert float(metrics["grad_norm"]) > 1e5  # norm reported pre-clip

    def test_bf16_moments_roundtrip(self):
        cfg = AdamWConfig(moment_dtype="bfloat16", warmup_steps=0,
                          peak_lr=1e-2)
        params = {"w": jnp.ones((16, 16), jnp.bfloat16)}
        opt = adamw_init(params, cfg)
        assert opt["m"]["w"].dtype == jnp.bfloat16
        g = {"w": jnp.full((16, 16), 0.1, jnp.bfloat16)}
        p2, opt2, _ = adamw_update(g, opt, params, jnp.asarray(5), cfg)
        assert p2["w"].dtype == jnp.bfloat16
        assert np.all(np.asarray(p2["w"], np.float32)
                      < np.asarray(params["w"], np.float32))

    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_cosine_schedule_bounds(self, step):
        cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=100, total_steps=10_000)
        lr = float(cosine_lr(jnp.asarray(step), cfg))
        assert 0.0 <= lr <= cfg.peak_lr * (1 + 1e-5)  # f32 representation

    def test_weight_decay_only_on_matrices(self):
        cfg = AdamWConfig(weight_decay=0.1, peak_lr=0.1, warmup_steps=0)
        params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        opt = adamw_init(params, cfg)
        zero_g = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        p2, _, _ = adamw_update(zero_g, opt, params, jnp.asarray(1000), cfg)
        assert float(jnp.max(jnp.abs(p2["b"] - 1.0))) < 1e-6  # no decay
        assert float(jnp.max(p2["w"])) < 1.0                  # decayed


class TestGradCompression:
    @pytest.mark.parametrize("mode", ["bf16", "int8"])
    def test_compression_bounded_error(self, mode):
        from repro.train.train_step import _compress
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        gc = _compress(g, mode)
        rel = float(jnp.linalg.norm(gc - g) / jnp.linalg.norm(g))
        assert rel < (0.01 if mode == "bf16" else 0.05)

    def test_training_with_int8_compression_still_learns(self):
        """End-to-end: int8-compressed grads still descend the loss."""
        from repro.train.train_step import _compress
        cfg = AdamWConfig(peak_lr=0.05, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
        params = {"w": jnp.zeros((4, 4))}
        opt = adamw_init(params, cfg)
        for step in range(200):
            g = jax.grad(lambda p: jnp.sum(jnp.square(p["w"] - 3.0)))(params)
            g = jax.tree.map(lambda x: _compress(x, "int8"), g)
            params, opt, _ = adamw_update(g, opt, params,
                                          jnp.asarray(step), cfg)
        assert float(jnp.max(jnp.abs(params["w"] - 3.0))) < 0.2


class TestGlobalNorm:
    @given(st.lists(st.floats(-10, 10), min_size=1, max_size=20))
    @settings(max_examples=30, deadline=None)
    def test_matches_numpy(self, vals):
        t = {"a": jnp.asarray(vals, jnp.float32)}
        got = float(global_norm(t))
        want = float(np.linalg.norm(np.asarray(vals, np.float32)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
