"""Sharded executor + plan partitioning: differential and invariant tests.

The sharded executor must be a pure execution-plan change — identical
outputs to the bucketed and dense executors on random, Zipf-skewed, and
degenerate schemas — and ``partition_plan`` must preserve the plan's
coverage/capacity structure on every shard while keeping the LPT balance
tight.  The in-process tests run at whatever local device count the main
test process has (1 on plain CPU); the subprocess test forces an 8-device
CPU mesh via ``XLA_FLAGS`` to exercise real multi-shard ``shard_map``
execution, like ``make bench-sharded`` does.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition_plan, plan_a2a
from repro.core.planner import reducer_work
from repro.mapreduce import (
    build_plan,
    get_executor,
    list_executors,
    make_executor,
    pairwise_similarity,
    run_reducers,
    run_reducers_sharded,
    some_pairs_similarity,
)
from repro.mapreduce.allpairs import _block_fn
from repro.mapreduce.engine import ReducerBucket, ReducerPlan


def _weights(kind: str, m: int, seed: int, q: float = 1.0):
    rng = np.random.default_rng(seed)
    return {
        "uniform": lambda: rng.uniform(0.05, 0.33, m),
        "zipf": lambda: np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45 * q),
        "one-giant": lambda: np.concatenate(
            [[0.8 * q], rng.uniform(0.02, 0.1, m - 1)]),
    }[kind]()


def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ----------------------------------------------------------------- registry
class TestExecutorRegistry:
    def test_all_executors_registered(self):
        core = {"bucketed", "dense", "fused", "sharded", "coded"}
        assert core.issubset(set(list_executors()))
        # the streaming executor registers lazily on first resolution
        get_executor("streaming")
        assert set(list_executors()) == core | {"streaming"}

    def test_unknown_executor_raises(self):
        with pytest.raises(ValueError, match="unknown executor"):
            get_executor("warp-drive")
        x = jnp.ones((4, 3), jnp.float32)
        with pytest.raises(ValueError, match="unknown executor"):
            pairwise_similarity(x, q=1.0, weights=np.full(4, 0.2),
                                executor="warp-drive")

    def test_instances_pass_through(self):
        ex = get_executor("bucketed")
        assert get_executor(ex) is ex

    def test_make_executor_is_instance_scoped(self):
        """Fresh instances own their counters: exercising one never moves
        another's — the PairwiseService isolation contract."""
        a = make_executor("fused")
        b = make_executor("fused")
        default = get_executor("fused")
        base_b = b.stats()["calls"]
        base_d = default.stats()["calls"]
        w = np.full(6, 0.3)
        plan = build_plan(plan_a2a(w, 1.0))
        x = jnp.ones((6, 3), jnp.float32)
        a.run(x, plan, _block_fn("dot", False))
        assert a.stats()["calls"] == 1
        assert b.stats()["calls"] == base_b
        assert default.stats()["calls"] == base_d

    def test_reset_is_instance_scoped(self):
        a = make_executor("sharded")
        a._count("calls")
        a.reset()
        assert a.stats()["calls"] == 0


# ----------------------------------------------------------- partition_plan
class TestPartitionPlan:
    @pytest.mark.parametrize("kind", ["uniform", "zipf", "one-giant"])
    @pytest.mark.parametrize("num_shards", [1, 3, 8])
    def test_coverage_and_capacity_preserved(self, kind, num_shards):
        """Every real reducer lands in exactly one shard with its idx/mask
        rows verbatim — coverage and reducer capacity are untouched."""
        m = 31
        plan = build_plan(plan_a2a(_weights(kind, m, seed=m), 1.0))
        part = partition_plan(plan, num_shards)
        all_rows = np.concatenate([r for r in part.shard_rows]
                                  ) if plan.num_reducers else np.zeros(0)
        np.testing.assert_array_equal(np.sort(all_rows),
                                      np.arange(plan.num_reducers))
        for rows, sub in zip(part.shard_rows, part.shards):
            assert sub.num_reducers == len(rows)
            np.testing.assert_array_equal(sub.idx, plan.idx[rows])
            np.testing.assert_array_equal(sub.mask, plan.mask[rows])

    @pytest.mark.parametrize("num_shards", [2, 5])
    def test_comm_cost_and_shipped_rows_conserved(self, num_shards):
        """The schema's communication cost is a cluster quantity: the
        per-shard shares must sum back to the plan totals (>= the lower
        bound the schema already certifies)."""
        plan = build_plan(plan_a2a(_weights("zipf", 40, seed=7), 1.0))
        part = partition_plan(plan, num_shards)
        assert int(part.shipped_rows.sum()) == int(plan.mask.sum())
        assert float(part.comm_cost.sum()) == pytest.approx(plan.comm_cost)
        assert sum(s.comm_cost for s in part.shards) == \
            pytest.approx(plan.comm_cost)
        if plan.lower_bound:
            assert part.comm_cost.sum() >= plan.lower_bound - 1e-9

    @pytest.mark.parametrize("kind", ["uniform", "zipf", "one-giant"])
    @pytest.mark.parametrize("num_shards", [2, 4, 8])
    def test_greedy_balance_bound(self, kind, num_shards):
        """LPT guarantee: max load <= mean + max single-reducer work, i.e.
        balance_factor <= 1 + S * max_work / total_work."""
        m = 48
        plan = build_plan(plan_a2a(_weights(kind, m, seed=m), 1.0))
        part = partition_plan(plan, num_shards)
        work = reducer_work(plan)
        if work.sum() > 0:
            bound = 1.0 + num_shards * float(work.max()) / float(work.sum())
            assert 1.0 <= part.balance_factor <= bound + 1e-9

    def test_zipf_m512_balance_meets_acceptance_bar(self):
        """The acceptance-criteria partition: Zipf m=512, 8 shards,
        LPT balance factor <= 1.25 (pure host work — no execution)."""
        rng = np.random.default_rng(0)
        w = np.clip(rng.zipf(1.6, 512).astype(np.float64) / 32.0,
                    0.01, 0.45)
        plan = build_plan(plan_a2a(w, 1.0))
        part = partition_plan(plan, 8)
        assert part.balance_factor <= 1.25, part.report()

    def test_sub_plan_buckets_are_consistent(self):
        """Sub-plan buckets re-index rows locally and keep idx/mask rows
        aligned with the sub-plan's own row order."""
        plan = build_plan(plan_a2a(_weights("zipf", 37, seed=3), 1.0))
        part = partition_plan(plan, 3)
        for sub in part.shards:
            seen = []
            for b in sub.buckets:
                assert np.all(b.rows >= 0)        # compact: no padding rows
                seen.extend(int(r) for r in b.rows)
                for i, local_row in enumerate(b.rows):
                    # bucket row i is sub-plan row local_row, truncated to
                    # the bucket width
                    np.testing.assert_array_equal(
                        b.idx[i], sub.idx[local_row][: b.width])
                    np.testing.assert_array_equal(
                        b.mask[i], sub.mask[local_row][: b.width])
            assert sorted(seen) == list(range(sub.num_reducers))

    def test_more_shards_than_reducers(self):
        """num_shards > R: singleton shards plus empties; coverage holds."""
        plan = build_plan(plan_a2a(np.full(4, 0.3), 1.0))
        part = partition_plan(plan, 16)
        nonempty = [r for r in part.shard_rows if len(r)]
        assert len(nonempty) == min(plan.num_reducers, 16)
        assert sum(len(r) for r in part.shard_rows) == plan.num_reducers

    def test_empty_plan(self):
        plan = build_plan(plan_a2a([], 1.0))
        part = partition_plan(plan, 4)
        assert part.balance_factor == 1.0
        assert all(len(r) == 0 for r in part.shard_rows)

    def test_bucketless_plan_uses_dense_width(self):
        """Plans with no capacity buckets fall back to the dense width as
        the per-reducer work unit."""
        idx = np.arange(6, dtype=np.int32).reshape(2, 3)
        mask = np.ones((2, 3), bool)
        plan = ReducerPlan(idx=idx, mask=mask, num_reducers=2,
                           comm_cost=6.0, max_inputs=3)
        part = partition_plan(plan, 2)
        assert [len(r) for r in part.shard_rows] == [1, 1]
        np.testing.assert_array_equal(part.widths, [3, 3])


# ------------------------------------------------------------- differential
KINDS = ["uniform", "zipf", "one-giant"]


class TestShardedExecutorDifferential:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("m", [5, 29])
    def test_pairwise_sharded_matches_bucketed_and_dense(self, kind, m):
        w = _weights(kind, m, seed=m)
        rng = np.random.default_rng(m)
        x = _rand(rng, (m, 6))
        schema = plan_a2a(w, 1.0)
        s_d, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        executor="dense")
        s_b, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        executor="bucketed")
        s_s, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        executor="sharded")
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_d),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("metric", ["dot", "l2", "cosine"])
    def test_metrics_agree(self, metric):
        m = 26
        w = _weights("zipf", m, seed=7)
        rng = np.random.default_rng(7)
        x = _rand(rng, (m, 8))
        schema = plan_a2a(w, 1.0)
        s_b, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        metric=metric, executor="bucketed")
        s_s, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        metric=metric, executor="sharded")
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)

    def test_dense_combine_run_matches_run_reducers(self):
        m = 23
        w = _weights("zipf", m, seed=3)
        plan = build_plan(plan_a2a(w, 1.0))
        rng = np.random.default_rng(5)
        x = _rand(rng, (m, 8))
        fn = _block_fn("dot", False)
        dense = run_reducers(x, plan, fn)
        sharded = run_reducers_sharded(x, plan, fn)
        assert sharded.shape == dense.shape
        np.testing.assert_allclose(np.asarray(sharded), np.asarray(dense),
                                   rtol=1e-5, atol=1e-5)

    def test_some_pairs_sharded_agrees(self):
        m = 20
        rng = np.random.default_rng(13)
        w = rng.uniform(0.02, 0.3, m)
        pairs = [(0, 1), (2, 9), (5, 17), (3, 4), (11, 12)]
        x = _rand(rng, (m, 8))
        s_b, _, sch = some_pairs_similarity(x, pairs, q=1.0, weights=w,
                                            executor="bucketed")
        s_s, _, _ = some_pairs_similarity(x, pairs, q=1.0, weights=w,
                                          schema=sch, executor="sharded")
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)

    def test_single_input_degenerate(self):
        x = jnp.ones((1, 4), jnp.float32)
        s_s, _, _ = pairwise_similarity(x, q=1.0, weights=[0.3],
                                        executor="sharded")
        s_b, _, _ = pairwise_similarity(x, q=1.0, weights=[0.3],
                                        executor="bucketed")
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_b))

    def test_all_masked_bucket(self):
        """Handmade plan whose only bucket is entirely padding rows."""
        idx = np.zeros((2, 3), np.int32)
        mask = np.zeros((2, 3), bool)
        plan = ReducerPlan(
            idx=idx, mask=mask, num_reducers=0, comm_cost=0.0, max_inputs=3,
            buckets=(ReducerBucket(width=3,
                                   rows=np.full(2, -1, np.int64),
                                   idx=idx, mask=mask),))
        x = jnp.ones((4, 5), jnp.float32)
        fn = _block_fn("dot", False)
        ex = make_executor("sharded")
        out = ex.run(x, plan, fn)
        assert ex.stats()["fallbacks"] == 1       # no real reducers
        assert float(jnp.abs(out).max()) == 0.0

    def test_non_gram_reducer_falls_back(self):
        m = 17
        w = _weights("zipf", m, seed=3)
        plan = build_plan(plan_a2a(w, 1.0))
        rng = np.random.default_rng(5)
        x = _rand(rng, (m, 4))

        def colsum(blk, msk):
            return jnp.sum(blk * msk[:, None], axis=0)

        ex = make_executor("sharded")
        from repro.mapreduce import run_reducers_bucketed
        sharded = ex.run(x, plan, colsum)
        buck = run_reducers_bucketed(x, plan, colsum)
        np.testing.assert_allclose(np.asarray(sharded), np.asarray(buck),
                                   rtol=1e-5, atol=1e-5)
        assert ex.stats()["fallbacks"] == 1
        assert ex.stats()["calls"] == 1

    def test_sharded_telemetry_recorded(self):
        m = 19
        w = _weights("uniform", m, seed=2)
        rng = np.random.default_rng(2)
        x = _rand(rng, (m, 4))
        ex = make_executor("sharded")
        schema = plan_a2a(w, 1.0)
        pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                            executor=ex)
        st = ex.stats()
        assert st["sharded"] == 1
        assert st["num_shards"] >= 1
        assert st["balance_factor"] >= 1.0


# ------------------------------------------------- forced 8-device CPU mesh
SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.core import partition_plan, plan_a2a
    from repro.mapreduce import build_plan, get_executor, \\
        pairwise_similarity

    rng = np.random.default_rng(0)
    for kind in ("uniform", "zipf", "one-giant"):
        m = 48
        if kind == "uniform":
            w = rng.uniform(0.05, 0.33, m)
        elif kind == "zipf":
            w = np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45)
        else:
            w = np.concatenate([[0.8], rng.uniform(0.02, 0.1, m - 1)])
        x = jnp.asarray(rng.normal(size=(m, 6)).astype(np.float32))
        schema = plan_a2a(w, 1.0)
        s_d, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        executor="dense")
        s_b, _, _ = pairwise_similarity(x, q=1.0, weights=w, schema=schema,
                                        executor="bucketed")
        s_s, plan, _ = pairwise_similarity(x, q=1.0, weights=w,
                                           schema=schema,
                                           executor="sharded")
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_d),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_b),
                                   rtol=1e-4, atol=1e-4)
        part = partition_plan(plan, 8)
        assert all(len(r) >= 0 for r in part.shard_rows)
    st = get_executor("sharded").stats()
    assert st["num_shards"] == 8, st
    print("SHARDED_OK", st["balance_factor"])
""")


def test_sharded_differential_on_8_device_mesh():
    """sharded == bucketed == dense under a real 8-shard shard_map mesh
    (subprocess: the main test process keeps its default device count)."""
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # force-host-device script must not probe TPU hardware
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "HOME": os.environ.get("HOME", "/tmp")},
    )
    assert "SHARDED_OK" in res.stdout, res.stdout + res.stderr
