"""Per-kernel allclose vs pure-jnp oracle, swept over shapes and dtypes.

All kernels run in interpret mode (CPU container); the same pallas_call
lowers to real TPU kernels on hardware.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.pairwise.pairwise import pairwise_gram
from repro.kernels.pairwise.ref import pairwise_gram_ref, pairwise_ref
from repro.kernels.pairwise.ops import pairwise_kernel
from repro.kernels.flash.flash_attention import flash_attention
from repro.kernels.flash.ref import attention_ref
from repro.kernels.flash.ops import mha
from repro.kernels.ssd.ssd import ssd_scan
from repro.kernels.ssd.ref import ssd_scan_ref
from repro.kernels.ssd.ops import ssd

# excluded from `make test-fast` (full arch/kernel e2e sweeps)
pytestmark = pytest.mark.slow


def rnd(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# ------------------------------------------------------------------ pairwise
class TestPairwiseKernel:
    @pytest.mark.parametrize("m,n,k", [
        (8, 8, 8), (16, 24, 32), (100, 60, 72), (130, 70, 300),
        (1, 5, 9), (257, 129, 65),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_gram_matches(self, m, n, k, dtype):
        rng = np.random.default_rng(m * 1000 + n + k)
        x, y = rnd(rng, (m, k), dtype), rnd(rng, (n, k), dtype)
        got = pairwise_gram(x, y, bm=32, bn=32, bk=64, interpret=True)
        ref = pairwise_gram_ref(x, y)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("metric", ["dot", "l2", "cosine"])
    def test_metrics(self, metric):
        rng = np.random.default_rng(7)
        x = rnd(rng, (33, 20), jnp.float32)
        got = pairwise_kernel(x, metric=metric, interpret=True,
                              bm=16, bn=16, bk=16)
        ref = pairwise_ref(x, metric=metric)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @given(st.integers(1, 50), st.integers(1, 50), st.integers(1, 40))
    @settings(max_examples=12, deadline=None)
    def test_property_shapes(self, m, n, k):
        rng = np.random.default_rng(m + 17 * n + 31 * k)
        x, y = rnd(rng, (m, k), jnp.float32), rnd(rng, (n, k), jnp.float32)
        got = pairwise_gram(x, y, bm=16, bn=16, bk=16, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(pairwise_gram_ref(x, y)),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ flash
class TestFlashAttention:
    @pytest.mark.parametrize("sq,skv,d", [
        (16, 16, 8), (64, 64, 16), (128, 128, 64), (100, 100, 32),
        (33, 65, 16),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, sq, skv, d, causal):
        if causal and sq != skv:
            pytest.skip("causal assumes aligned positions")
        rng = np.random.default_rng(sq + skv + d)
        q = rnd(rng, (sq, d), jnp.float32)
        k = rnd(rng, (skv, d), jnp.float32)
        v = rnd(rng, (skv, d), jnp.float32)
        got = flash_attention(q, k, v, causal=causal, interpret=True,
                              bq=32, bk=32)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [4, 16, 64])
    def test_sliding_window(self, window):
        rng = np.random.default_rng(window)
        s, d = 96, 16
        q, k, v = (rnd(rng, (s, d), jnp.float32) for _ in range(3))
        got = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True, bq=32, bk=32)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        rng = np.random.default_rng(3)
        s, d = 64, 32
        q, k, v = (rnd(rng, (s, d), jnp.bfloat16) for _ in range(3))
        got = flash_attention(q, k, v, causal=True, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_gqa_mha_wrapper(self):
        rng = np.random.default_rng(11)
        B, S, Hq, Hkv, D = 2, 40, 8, 2, 16
        q = rnd(rng, (B, S, Hq, D), jnp.float32)
        k = rnd(rng, (B, S, Hkv, D), jnp.float32)
        v = rnd(rng, (B, S, Hkv, D), jnp.float32)
        got = mha(q, k, v, causal=True, use_kernel=True, interpret=True,
                  bq=16, bk=16)
        ref = mha(q, k, v, causal=True, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ ssd
class TestSSD:
    @pytest.mark.parametrize("s,p,n,chunk", [
        (32, 8, 4, 8), (64, 16, 16, 16), (100, 8, 8, 32), (128, 32, 16, 128),
        (7, 4, 4, 8),
    ])
    def test_matches_ref(self, s, p, n, chunk):
        rng = np.random.default_rng(s + p + n)
        x = rnd(rng, (s, p), jnp.float32)
        b = rnd(rng, (s, n), jnp.float32)
        c = rnd(rng, (s, n), jnp.float32)
        log_a = jnp.asarray(-np.abs(rng.normal(size=s)).astype(np.float32))
        got = ssd_scan(x, log_a, b, c, chunk=chunk, interpret=True)
        ref = ssd_scan_ref(x, log_a, b, c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_state_carry_across_chunks(self):
        # strong decay contrast ensures cross-chunk state actually matters
        rng = np.random.default_rng(0)
        s, p, n = 64, 8, 8
        x = rnd(rng, (s, p), jnp.float32)
        b = rnd(rng, (s, n), jnp.float32)
        c = rnd(rng, (s, n), jnp.float32)
        log_a = jnp.full((s,), -0.01)  # nearly no decay: long memory
        got = ssd_scan(x, log_a, b, c, chunk=16, interpret=True)
        ref = ssd_scan_ref(x, log_a, b, c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_batched_wrapper(self):
        rng = np.random.default_rng(1)
        B, S, H, P, N = 2, 24, 3, 8, 4
        x = rnd(rng, (B, S, H, P), jnp.float32)
        b = rnd(rng, (B, S, H, N), jnp.float32)
        c = rnd(rng, (B, S, H, N), jnp.float32)
        la = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))).astype(np.float32))
        got = ssd(x, la, b, c, chunk=8, use_kernel=True, interpret=True)
        ref = ssd(x, la, b, c, use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
