"""Strategy-registry planner: estimator exactness, cache, some-pairs.

The contract that lets ``plan_a2a(method='auto')`` skip materialization is
that every registered strategy's ``estimate`` equals the communication cost
of the schema its ``build`` produces.  These tests enforce that invariant
per strategy and end-to-end (estimate-based auto == materialize-everything
portfolio), plus the PlanCache semantics and ``plan_some_pairs`` validity.
"""

import numpy as np
import pytest

from repro.core import (
    InfeasibleError,
    PLAN_CACHE,
    estimate_a2a,
    naive_pairs,
    plan_a2a,
    plan_a2a_materialized,
    plan_some_pairs,
    plan_unit,
    some_pairs_comm_lower_bound,
)
from repro.core.schema import MappingSchema
from repro.core.strategies import (
    A2AProfile,
    PlanCache,
    a2a_portfolio,
    unit_estimates,
)


@pytest.fixture(autouse=True)
def _fresh_cache():
    PLAN_CACHE.clear()
    yield
    PLAN_CACHE.clear()


def unit_schema(reducers, bw, k) -> MappingSchema:
    return MappingSchema(np.asarray(bw, float), float(k) * 10.0,
                         [[i] for i in range(len(bw))], reducers,
                         algorithm="unit")


# ------------------------------------------------- estimator == built cost
class TestUnitEstimates:
    @pytest.mark.parametrize("n,k", [
        (5, 2), (23, 2), (64, 2),              # alg_even k=2
        (10, 4), (40, 6), (100, 10),           # alg_even larger k
        (7, 3), (16, 3), (31, 3), (23, 5),     # alg_odd
        (25, 5), (49, 7), (20, 5),             # au_square (+ filtered)
        (30, 6), (11, 4), (29, 7),             # au_projective / alg3
        (27, 3), (16, 2), (125, 5),            # alg4
        (3, 8), (2, 2),                        # single
    ])
    def test_estimate_matches_built_cost(self, n, k):
        rng = np.random.default_rng(n * 100 + k)
        bw = rng.uniform(0.1, 1.0, n)
        cands = unit_estimates(bw, k)
        assert cands, f"no unit strategy for n={n}, k={k}"
        for strat, est in cands:
            reds = strat.build(n, k)
            s = unit_schema(reds, bw, k)
            s.validate("a2a")
            assert np.isclose(est, s.communication_cost(), rtol=1e-9), (
                f"{strat.name}: estimate {est} != built "
                f"{s.communication_cost()} at n={n}, k={k}")

    def test_every_registered_strategy_exercised(self):
        seen = set()
        for n, k in [(23, 2), (31, 3), (25, 5), (30, 6), (11, 4),
                     (27, 3), (3, 8), (127, 12)]:
            bw = np.ones(n)
            for strat, _ in unit_estimates(bw, k):
                seen.add(strat.name)
        assert {"single", "alg_even", "alg_odd", "au_square",
                "au_projective", "alg3", "alg4"} <= seen

    def test_plan_unit_api_unchanged(self):
        reds, name = plan_unit(25, 5)
        assert name == "au_square"
        s = unit_schema(reds, np.ones(25), 5)
        s.validate("a2a")


class TestA2AEstimates:
    def test_strategy_estimates_exact(self):
        rng = np.random.default_rng(7)
        for _ in range(25):
            m = int(rng.integers(3, 50))
            w = rng.uniform(0.01, 0.5, m)
            if w.sum() <= 1.0:
                continue
            prof = A2AProfile(w, 1.0)
            for strat, est in a2a_portfolio(prof):
                s = strat.build(prof)
                assert np.isclose(est, s.communication_cost(), rtol=1e-9), (
                    f"{strat.name}: {est} != {s.communication_cost()}")

    def test_auto_matches_materialized_portfolio(self):
        rng = np.random.default_rng(11)
        for _ in range(20):
            m = int(rng.integers(2, 60))
            w = rng.uniform(0.01, 0.5, m)
            fast = plan_a2a(w, 1.0)
            fast.validate("a2a")
            slow = plan_a2a_materialized(w, 1.0)
            assert fast.communication_cost() <= \
                slow.communication_cost() + 1e-9

    def test_estimate_a2a_no_materialization_matches_plan(self):
        rng = np.random.default_rng(13)
        w = rng.uniform(0.02, 0.4, 40)
        name, est = estimate_a2a(w, 1.0)
        s = plan_a2a(w, 1.0)
        assert np.isclose(est, s.communication_cost(), rtol=1e-9)
        assert name in s.algorithm

    def test_big_input_estimate(self):
        w = np.array([0.6] + [0.05] * 20)
        name, est = estimate_a2a(w, 1.0)
        s = plan_a2a(w, 1.0)
        assert name.startswith("big-input")
        assert np.isclose(est, s.communication_cost(), rtol=1e-9)


# ------------------------------------------------------------- lower bounds
class TestLowerBoundWiring:
    def test_every_plan_carries_lower_bound(self):
        rng = np.random.default_rng(3)
        w = rng.uniform(0.02, 0.4, 30)
        for schema in (plan_a2a(w, 1.0),
                       plan_a2a(w, 1.0, method="binpack-k2"),
                       plan_a2a([0.6] + [0.05] * 10, 1.0),
                       plan_a2a([0.1, 0.2], 1.0),
                       naive_pairs(w, 1.0)):
            assert schema.lower_bound is not None
            gap = schema.optimality_gap()
            assert gap is not None and gap >= 0.999, schema.algorithm

    def test_gap_none_without_bound(self):
        s = MappingSchema(np.ones(2), 2.0, [[0], [1]], [[0, 1]])
        assert s.optimality_gap() is None


# ------------------------------------------------------------------- cache
class TestPlanCache:
    def test_permutation_hits_cache(self):
        rng = np.random.default_rng(5)
        w = rng.uniform(0.02, 0.4, 25)
        s1 = plan_a2a(w, 1.0)
        misses = PLAN_CACHE.misses
        perm = rng.permutation(len(w))
        s2 = plan_a2a(w[perm], 1.0)
        assert PLAN_CACHE.misses == misses     # pure hit
        assert PLAN_CACHE.hits >= 1
        s2.validate("a2a")
        assert np.isclose(s1.communication_cost(), s2.communication_cost())

    def test_remap_preserves_input_identity(self):
        w = np.array([0.3, 0.1, 0.25, 0.2])
        plan_a2a(w, 1.0)                       # prime the cache
        perm = np.array([2, 0, 3, 1])
        s = plan_a2a(w[perm], 1.0)
        # input i of the permuted call must carry weight w[perm][i]
        np.testing.assert_allclose(s.weights, w[perm])
        s.validate("a2a")

    def test_lru_eviction(self):
        cache = PlanCache(maxsize=2)
        cache.put(("a",), 1)
        cache.put(("b",), 2)
        cache.put(("c",), 3)
        assert len(cache) == 2
        assert cache.get(("a",)) is None
        assert cache.get(("c",)) == 3

    def test_use_cache_false_bypasses(self):
        w = np.full(10, 0.3)
        plan_a2a(w, 1.0, use_cache=False)
        assert len(PLAN_CACHE) == 0

    def test_registering_strategy_invalidates_cache(self):
        from repro.core import A2A_REGISTRY, register_a2a_strategy
        w = np.full(10, 0.3)
        plan_a2a(w, 1.0)
        assert len(PLAN_CACHE) > 0
        register_a2a_strategy(lambda prof: [])     # no-op strategy factory
        try:
            assert len(PLAN_CACHE) == 0            # stale plans dropped
        finally:
            A2A_REGISTRY.pop()


# -------------------------------------------------------------- some pairs
class TestPlanSomePairs:
    def _random_instance(self, seed, m=30, density=0.2):
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.02, 0.3, m)
        all_pairs = [(i, j) for i in range(m) for j in range(i + 1, m)]
        take = max(1, int(density * len(all_pairs)))
        idx = rng.choice(len(all_pairs), size=take, replace=False)
        return w, [all_pairs[i] for i in idx]

    @pytest.mark.parametrize("density", [0.02, 0.2, 0.8])
    def test_valid_and_bounded(self, density):
        w, pairs = self._random_instance(17, density=density)
        s = plan_some_pairs(w, 1.0, pairs)
        s.validate("some", required_pairs=pairs)
        assert s.lower_bound is not None
        assert s.communication_cost() >= \
            some_pairs_comm_lower_bound(w, 1.0, pairs) * 0.999

    def test_estimated_cost_exact(self):
        for density in (0.05, 0.3):
            w, pairs = self._random_instance(23, density=density)
            s = plan_some_pairs(w, 1.0, pairs)
            assert np.isclose(s.meta["estimated_cost"],
                              s.communication_cost(), rtol=1e-9), s.algorithm

    def test_sparse_cheaper_than_a2a(self):
        w, pairs = self._random_instance(29, m=40, density=0.05)
        sparse = plan_some_pairs(w, 1.0, pairs)
        dense = plan_a2a(w, 1.0)
        assert sparse.communication_cost() < dense.communication_cost()

    def test_duplicate_and_reversed_pairs_ignored(self):
        w = np.full(6, 0.2)
        s1 = plan_some_pairs(w, 1.0, [(0, 1), (1, 0), (0, 1), (2, 3)])
        assert s1.meta["required_pairs"] == 2
        s1.validate("some", required_pairs=[(0, 1), (2, 3)])

    def test_infeasible_pair_raises(self):
        with pytest.raises(InfeasibleError):
            plan_some_pairs([0.7, 0.6, 0.1], 1.0, [(0, 1)])

    def test_empty_pairs(self):
        s = plan_some_pairs([0.2, 0.3], 1.0, [])
        assert s.num_reducers == 0
        assert s.communication_cost() == 0.0

    def test_big_incident_input_falls_back(self):
        # one input > q/2 rules out the sparse-bin strategy but the pair
        # and a2a strategies still apply
        w = [0.6, 0.1, 0.1, 0.1]
        pairs = [(0, 1), (2, 3)]
        s = plan_some_pairs(w, 1.0, pairs)
        s.validate("some", required_pairs=pairs)


# ---------------------------------------------------------------------------
# Property tests (hypothesis-optional): rectangular and some-pairs planners
# ---------------------------------------------------------------------------
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import estimate_x2y, plan_x2y, x2y_comm_lower_bound  # noqa: E402


class TestX2YProperties:
    """Random rectangular profiles: the X2Y planner's schema covers
    exactly the cross pairs, respects capacity, and its recorded estimate
    equals the built schema's measured communication cost."""

    @staticmethod
    def _profile(seed):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(1, 12))
        n = int(rng.integers(1, 12))
        wx = rng.uniform(0.02, 0.45, m)
        wy = rng.uniform(0.02, 0.45, n)
        return wx, wy

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_random_rect_profile_valid_and_exact(self, seed):
        wx, wy = self._profile(seed)
        q = float(wx.max() + wy.max()) * np.random.default_rng(
            seed + 1).uniform(1.0, 3.0)
        schema = plan_x2y(wx, wy, q)
        m, n = len(wx), len(wy)
        schema.validate("x2y", x_ids=range(m), y_ids=range(m, m + n))
        # estimate == built cost (the contract that lets the b-sweep run
        # estimate-only and materialize just the winner)
        assert np.isclose(schema.meta["estimated_cost"],
                          schema.communication_cost(), rtol=1e-9)
        # ... and the sweep's own closed form agrees
        b, est = estimate_x2y(wx, wy, q)
        assert np.isclose(est, schema.communication_cost(), rtol=1e-9)
        assert schema.communication_cost() >= \
            x2y_comm_lower_bound(wx, wy, q) - 1e-9
        assert schema.lower_bound == pytest.approx(
            x2y_comm_lower_bound(wx, wy, q))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_covers_exactly_the_cross_pairs(self, seed):
        wx, wy = self._profile(seed)
        q = float(wx.max() + wy.max() + 0.1)
        m, n = len(wx), len(wy)
        schema = plan_x2y(wx, wy, q)
        met = set()
        for ids in schema.expand():
            xs = [i for i in ids if i < m]
            ys = [j for j in ids if j >= m]
            met.update((i, j) for i in xs for j in ys)
            # no same-side pair is ever *required* by X2Y; reducers are
            # one X bin against one Y bin so none can co-ship two bins of
            # the same side beyond what one bin holds
        want = {(i, j) for i in range(m) for j in range(m, m + n)}
        assert met == want


class TestSomePairsProperties:
    """Random required-pair subsets: the winning some-pairs strategy's
    schema covers exactly the required pairs and the estimate used for
    strategy selection equals the built cost."""

    @staticmethod
    def _instance(seed):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(2, 25))
        w = rng.uniform(0.02, 0.4, m)
        density = float(rng.uniform(0.05, 0.9))
        cand = [(i, j) for i in range(m) for j in range(i + 1, m)]
        take = rng.random(len(cand)) < density
        pairs = [p for p, t in zip(cand, take) if t]
        return w, pairs

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_random_pair_subset_valid_and_exact(self, seed):
        w, pairs = self._instance(seed)
        q = 1.0
        schema = plan_some_pairs(w, q, pairs)
        schema.validate("some", required_pairs=pairs)
        if not pairs:
            assert schema.communication_cost() == 0.0
            return
        assert np.isclose(schema.meta["estimated_cost"],
                          schema.communication_cost(), rtol=1e-9), \
            schema.algorithm
        assert schema.communication_cost() >= \
            some_pairs_comm_lower_bound(w, q, pairs) - 1e-9

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_partial_cover_never_ships_pair_free_inputs_extra(self, seed):
        w, pairs = self._instance(seed)
        schema = plan_some_pairs(w, 1.0, pairs)
        if not schema.meta.get("partial_cover", False):
            return
        incident = {i for p in pairs for i in p}
        placed = {i for b in schema.bins for i in b}
        assert placed <= incident
