"""Schema conformance: every registry strategy AND every registry
executor, on any weight profile, must respect the paper's bounds.

Strategy level — three properties for every strategy x profile:

  (a) coverage  — every required pair (A2A), cross pair (X2Y), or listed
      pair (some-pairs) meets at >= 1 reducer;
  (b) capacity  — no reducer's deduplicated load exceeds q;
  (c) bound     — measured communication_cost() >= the instance's
      replication-rate lower bound (a cost below the proven lower bound
      means the schema under-ships and cannot be covering).

Executor level — ``TestExecutorConformanceMatrix`` runs every *registry
executor* x {allpairs, x2y, some-pairs} workload x profile cell: the
planned schema passes (a)-(c) and the executor's assembled matrix matches
the dense oracle executor allclose.  Executors are discovered from the
registry at collection time (after importing ``repro.stream`` so the
lazily-registered streaming executor participates), so a new
``register_executor`` entry inherits the whole matrix automatically.

Deterministic profile sweeps run everywhere; the @given variants fuzz the
same properties when hypothesis is installed (tests/_hypothesis_compat
turns them into per-test skips otherwise).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    InfeasibleError,
    a2a_comm_lower_bound,
    a2a_unit_comm_lower_bound,
    plan_a2a,
    plan_some_pairs,
    plan_x2y,
    some_pairs_comm_lower_bound,
    x2y_comm_lower_bound,
)
from repro.core.schema import MappingSchema
from repro.core.strategies import (
    A2AProfile,
    UNIT_REGISTRY,
    a2a_portfolio,
)

TOL = 1e-9


def _registry_executors() -> list[str]:
    """Registry executor names at collection time.  Importing
    ``repro.stream`` first makes the lazily-registered streaming executor
    participate; anything registered later via ``register_executor``
    inherits the matrix on the next collection."""
    import repro.stream  # noqa: F401 — registers "streaming"
    from repro.mapreduce import list_executors
    return list_executors()


def profile(kind: str, m: int, seed: int, q: float = 1.0) -> np.ndarray:
    """Deterministic weight profiles exercising the planner's case split."""
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.uniform(0.05, 0.33, m)
    if kind == "zipf":
        return np.clip(rng.zipf(1.7, m) / 24.0, 0.02, 0.45 * q)
    if kind == "equal":
        return np.full(m, 0.21 * q)
    if kind == "one-giant":
        w = rng.uniform(0.02, 0.12, m)
        w[0] = 0.8 * q                       # big-input path (Section 9)
        return w
    if kind == "near-half":
        return rng.uniform(0.30 * q, 0.49 * q, m)
    raise ValueError(kind)


PROFILES = [
    (kind, m, seed)
    for kind in ("uniform", "zipf", "equal", "one-giant", "near-half")
    for m, seed in [(7, 0), (23, 1), (48, 2)]
]


def _check_a2a(schema: MappingSchema, w, q) -> None:
    schema.validate("a2a")                       # coverage + capacity
    lb = a2a_comm_lower_bound(w, q)
    assert schema.communication_cost() >= lb - TOL, (
        schema.algorithm, schema.communication_cost(), lb)


# --------------------------------------------------------------- A2A registry
class TestA2ARegistryConformance:
    @pytest.mark.parametrize("kind,m,seed", PROFILES)
    def test_every_portfolio_strategy_conforms(self, kind, m, seed):
        """Not just the argmin winner: every applicable registered strategy
        must build a valid schema (the portfolio may pick any of them on a
        different profile)."""
        q = 1.0
        w = profile(kind, m, seed, q)
        if kind == "one-giant":
            pytest.skip("big-input profiles bypass the portfolio (Sec 9)")
        prof = A2AProfile(np.sort(w)[::-1], q)
        cands = a2a_portfolio(prof)
        assert cands, "no applicable strategy"
        for strat, est in cands:
            schema = strat.build(prof)
            _check_a2a(schema, prof.w, q)
            assert schema.communication_cost() == pytest.approx(est), (
                strat.name)

    @pytest.mark.parametrize("kind,m,seed", PROFILES)
    def test_planner_auto_conforms(self, kind, m, seed):
        q = 1.0
        w = profile(kind, m, seed, q)
        schema = plan_a2a(w, q)
        _check_a2a(schema, w, q)

    @given(st.lists(st.floats(0.01, 0.45), min_size=2, max_size=40),
           st.integers(0, 3))
    @settings(max_examples=40, deadline=None)
    def test_property_random_profiles(self, weights, _salt):
        w = np.asarray(weights)
        schema = plan_a2a(w, 1.0)
        _check_a2a(schema, w, 1.0)


# ------------------------------------------------------------- unit registry
class TestUnitRegistryConformance:
    @pytest.mark.parametrize("n", [2, 3, 5, 8, 13, 21, 40])
    @pytest.mark.parametrize("k", [2, 3, 4, 5, 7, 8])
    def test_every_unit_strategy_conforms(self, n, k):
        w = np.ones(n)
        for strat in UNIT_REGISTRY:
            if strat.name == "single" and n > k:
                continue
            if not strat.applicable(n, k):
                continue
            reducers = strat.build(n, k)
            schema = MappingSchema(
                w, float(k), [[i] for i in range(n)], reducers,
                algorithm=strat.name)
            schema.validate("a2a")
            lb = a2a_unit_comm_lower_bound(n, k)
            assert schema.communication_cost() >= lb - TOL, (
                strat.name, n, k, schema.communication_cost(), lb)

    @given(st.integers(2, 40), st.integers(2, 8))
    @settings(max_examples=40, deadline=None)
    def test_property_unit_strategies(self, n, k):
        self.test_every_unit_strategy_conforms(n, k)


# ----------------------------------------------------------------------- X2Y
class TestX2YConformance:
    @pytest.mark.parametrize("mx,my,seed", [(5, 7, 0), (16, 9, 1),
                                            (24, 24, 2), (1, 13, 3)])
    @pytest.mark.parametrize("kind", ["uniform", "zipf"])
    def test_cross_pairs_conform(self, mx, my, seed, kind):
        q = 1.0
        wx = profile(kind, mx, seed, q) / 2.0
        wy = profile(kind, my, seed + 100, q) / 2.0
        schema = plan_x2y(wx, wy, q)
        schema.validate("x2y", x_ids=range(mx),
                        y_ids=range(mx, mx + my))
        lb = x2y_comm_lower_bound(wx, wy, q)
        assert schema.communication_cost() >= lb - TOL

    @given(st.lists(st.floats(0.01, 0.4), min_size=1, max_size=20),
           st.lists(st.floats(0.01, 0.4), min_size=1, max_size=20))
    @settings(max_examples=30, deadline=None)
    def test_property_x2y(self, wx, wy):
        wx, wy = np.asarray(wx), np.asarray(wy)
        schema = plan_x2y(wx, wy, 1.0)
        schema.validate("x2y", x_ids=range(len(wx)),
                        y_ids=range(len(wx), len(wx) + len(wy)))
        lb = x2y_comm_lower_bound(wx, wy, 1.0)
        assert schema.communication_cost() >= lb - TOL


# ----------------------------------------------------------- partition_plan
class TestPartitionConformance:
    """Sharding a plan must not change what the schema promises: every
    reducer still exists exactly once with its exact input set (coverage +
    capacity), and the per-shard communication shares sum back to the
    schema's measured cost, which stays >= the instance's lower bound."""

    @pytest.mark.parametrize("kind,m,seed", PROFILES)
    @pytest.mark.parametrize("num_shards", [3, 8])
    def test_partition_preserves_schema_invariants(self, kind, m, seed,
                                                   num_shards):
        from repro.core import partition_plan
        from repro.core.planner import reducer_work
        from repro.mapreduce import build_plan

        q = 1.0
        w = profile(kind, m, seed, q)
        schema = plan_a2a(w, q)
        _check_a2a(schema, w, q)                 # the un-sharded baseline
        plan = build_plan(schema)
        part = partition_plan(plan, num_shards)

        # coverage: every real reducer in exactly one shard, rows verbatim
        all_rows = np.sort(np.concatenate(list(part.shard_rows)))
        np.testing.assert_array_equal(all_rows,
                                      np.arange(plan.num_reducers))
        for rows, sub in zip(part.shard_rows, part.shards):
            np.testing.assert_array_equal(sub.idx, plan.idx[rows])
            np.testing.assert_array_equal(sub.mask, plan.mask[rows])

        # comm conservation + lower bound: shares sum to the measured cost
        assert float(part.comm_cost.sum()) == pytest.approx(plan.comm_cost)
        lb = a2a_comm_lower_bound(w, q)
        assert float(part.comm_cost.sum()) >= lb - TOL

        # balance: within the greedy guarantee
        work = reducer_work(plan)
        if work.sum() > 0:
            bound = 1.0 + num_shards * float(work.max()) / float(work.sum())
            assert 1.0 <= part.balance_factor <= bound + TOL


# ---------------------------------------------------------------- some-pairs
class TestSomePairsConformance:
    @pytest.mark.parametrize("m,npairs,seed", [(10, 4, 0), (30, 40, 1),
                                               (40, 200, 2), (12, 66, 3)])
    def test_required_pairs_conform(self, m, npairs, seed):
        q = 1.0
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.02, 0.3, m)
        pairs = {tuple(sorted(rng.choice(m, 2, replace=False)))
                 for _ in range(npairs)}
        pairs = [p for p in pairs if p[0] != p[1]]
        schema = plan_some_pairs(w, q, pairs)
        schema.validate("some", required_pairs=pairs)
        lb = some_pairs_comm_lower_bound(w, q, pairs)
        assert schema.communication_cost() >= lb - TOL

    def test_infeasible_pair_raises(self):
        w = np.array([0.7, 0.6, 0.1])
        with pytest.raises(InfeasibleError):
            plan_some_pairs(w, 1.0, [(0, 1)])


# ----------------------------------------------- executor conformance matrix
def xy_profile(kind: str, seed: int, q: float = 1.0):
    """Two-sided weight profiles for the executor matrix.  ``y1`` / ``x1``
    are the degenerate single-input sides (|Y| = 1 / |X| = 1); square
    workloads use the concatenation."""
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.uniform(0.05, 0.30, 9), rng.uniform(0.05, 0.30, 7)
    if kind == "zipf":
        return (np.clip(rng.zipf(1.7, 9) / 24.0, 0.02, 0.40 * q),
                np.clip(rng.zipf(1.7, 7) / 24.0, 0.02, 0.40 * q))
    if kind == "one-giant":
        wx = rng.uniform(0.02, 0.10, 9)
        wx[0] = 0.55 * q
        return wx, rng.uniform(0.02, 0.10, 7)
    if kind == "y1":
        return rng.uniform(0.05, 0.30, 8), np.array([0.3 * q])
    if kind == "x1":
        return np.array([0.3 * q]), rng.uniform(0.05, 0.30, 8)
    raise ValueError(kind)


class TestExecutorConformanceMatrix:
    """Every registry executor x workload x profile cell.

    Per cell: the planned schema passes coverage + capacity
    (``schema.validate``), its measured cost is >= the instance's lower
    bound, and the executor's assembled output matches the dense oracle
    executor allclose.  Executor names come from the live registry
    (:func:`_registry_executors`), so custom executors registered via
    ``register_executor`` inherit every cell without editing this file.
    """

    D = 5
    Q = 1.0

    @pytest.mark.parametrize("executor", _registry_executors())
    @pytest.mark.parametrize("kind",
                             ["uniform", "zipf", "one-giant", "y1", "x1"])
    @pytest.mark.parametrize("workload",
                             ["allpairs", "x2y", "some_pairs", "block"])
    def test_cell(self, executor, kind, workload):
        from repro.mapreduce.allpairs import (
            pairwise_similarity,
            pairwise_similarity_block,
            some_pairs_similarity,
            x2y_similarity,
        )
        q = self.Q
        wx, wy = xy_profile(kind, seed=7, q=q)
        rng = np.random.default_rng(11)

        if workload == "x2y":
            mx, my = len(wx), len(wy)
            x = jnp.asarray(rng.normal(size=(mx, self.D)), jnp.float32)
            y = jnp.asarray(rng.normal(size=(my, self.D)), jnp.float32)
            schema = plan_x2y(wx, wy, q)
            schema.validate("x2y", x_ids=range(mx),
                            y_ids=range(mx, mx + my))
            lb = x2y_comm_lower_bound(wx, wy, q)
            assert schema.communication_cost() >= lb - TOL
            out, plan, _ = x2y_similarity(x, y, q=q, schema=schema,
                                          executor=executor)
            ref, _, _ = x2y_similarity(x, y, q=q, schema=schema,
                                       executor="dense")
        elif workload == "block":
            # block-served sub-matrices against the dense (m, m) oracle:
            # the executor-generic run_block default must agree cell-for-
            # cell on a full cross-check grid, uneven tail blocks included
            w = np.concatenate([wx, wy])
            m = len(w)
            x = jnp.asarray(rng.normal(size=(m, self.D)), jnp.float32)
            schema = plan_a2a(w, q)
            if schema.meta.get("bins_overlap", False):
                pytest.skip("block serving requires disjoint bins "
                            "(hybrid/big-input schemas stay on build_plan)")
            schema.validate("a2a")
            ref, _, _ = pairwise_similarity(x, q=q, schema=schema,
                                            executor="dense")
            ref = np.asarray(ref)
            B = max(2, m // 2 - 1)
            sparse = None
            for i0 in range(0, m, B):
                for j0 in range(0, m, B):
                    i1, j1 = min(i0 + B, m), min(j0 + B, m)
                    blk, sparse, _ = pairwise_similarity_block(
                        x, i0, i1, j0, j1, q=q, schema=schema,
                        executor=executor)
                    np.testing.assert_allclose(
                        np.asarray(blk), ref[i0:i1, j0:j1],
                        rtol=1e-5, atol=1e-5,
                        err_msg=f"block [{i0}:{i1})x[{j0}:{j1})")
            assert sparse is not None and sparse.num_reducers > 0
            return
        else:
            w = np.concatenate([wx, wy])
            m = len(w)
            x = jnp.asarray(rng.normal(size=(m, self.D)), jnp.float32)
            if workload == "allpairs":
                schema = plan_a2a(w, q)
                schema.validate("a2a")
                lb = a2a_comm_lower_bound(w, q)
                assert schema.communication_cost() >= lb - TOL
                out, plan, _ = pairwise_similarity(
                    x, q=q, schema=schema, executor=executor)
                ref, _, _ = pairwise_similarity(
                    x, q=q, schema=schema, executor="dense")
            else:
                pairs = sorted({
                    tuple(sorted(rng.choice(m, 2, replace=False)))
                    for _ in range(2 * m)})
                schema = plan_some_pairs(w, q, pairs)
                schema.validate("some", required_pairs=pairs)
                lb = some_pairs_comm_lower_bound(w, q, pairs)
                assert schema.communication_cost() >= lb - TOL
                out, plan, _ = some_pairs_similarity(
                    x, pairs, q=q, schema=schema, executor=executor)
                ref, _, _ = some_pairs_similarity(
                    x, pairs, q=q, schema=schema, executor="dense")

        assert plan.num_reducers > 0
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
